"""Multi-device streaming clustering: shard the stream over a device mesh,
cluster locally, merge through the contracted global pass (DESIGN.md §3).

Re-execs itself with 8 fake host devices so it works on any machine.

    PYTHONPATH=src python examples/distributed_cluster.py
"""

import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax  # noqa: E402

from repro.cluster import ClusterConfig, avg_f1, cluster, modularity  # noqa: E402
from repro.graph.generators import sbm_stream  # noqa: E402


def main():
    mesh = jax.make_mesh((8,), ("data",))
    n = 10_000
    edges, truth = sbm_stream(n, 500, avg_degree=12, p_intra=0.8, seed=2)
    print(f"devices: {len(jax.devices())}; stream: {len(edges)} edges")

    seq = cluster(edges, ClusterConfig(n=n, v_max=48, backend="dense"))
    print(f"[1-stream ] Q={modularity(edges, seq.labels):.3f} "
          f"F1={avg_f1(seq.labels, truth):.3f}")

    dist = cluster(
        edges,
        ClusterConfig(n=n, v_max=48, backend="distributed", chunk=1024),
        mesh=mesh,
    )
    print(f"[8-shard  ] Q={modularity(edges, dist.labels):.3f} "
          f"F1={avg_f1(dist.labels, truth):.3f} ({dist.info})")


if __name__ == "__main__":
    main()
