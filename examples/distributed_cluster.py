"""Multi-device streaming clustering: shard the stream over a device mesh,
cluster locally, merge through the contracted global pass (DESIGN.md §3).

Re-execs itself with 8 fake host devices so it works on any machine.

    PYTHONPATH=src python examples/distributed_cluster.py
"""

import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.distributed import distributed_cluster  # noqa: E402
from repro.core.metrics import avg_f1, modularity  # noqa: E402
from repro.core.streaming import canonical_labels, cluster_stream_dense  # noqa: E402
from repro.graph.generators import sbm_stream  # noqa: E402


def main():
    mesh = jax.make_mesh((8,), ("data",))
    n = 10_000
    edges, truth = sbm_stream(n, 500, avg_degree=12, p_intra=0.8, seed=2)
    print(f"devices: {len(jax.devices())}; stream: {len(edges)} edges")

    c_seq, _, _ = cluster_stream_dense(edges, 48, n)
    print(f"[1-stream ] Q={modularity(edges, c_seq):.3f} "
          f"F1={avg_f1(canonical_labels(c_seq), truth):.3f}")

    c_dist, info = distributed_cluster(edges, 48, n, mesh=mesh, chunk=1024)
    print(f"[8-shard  ] Q={modularity(edges, c_dist):.3f} "
          f"F1={avg_f1(canonical_labels(c_dist), truth):.3f} ({info})")


if __name__ == "__main__":
    main()
