"""Paper §2.5: one pass over the stream, many v_max values, edge-free
selection — then compare the selector's pick to the hindsight-best.

The sweep is a resumable streaming backend: the stream arrives from a
``GeneratorSource`` (never materialized by the clusterer) and the measured
peak edge buffer is O(batch_edges) while the sweep state is ``(2A+1) n``
ints.  Q/F1 below need the whole graph, so the *evaluation* materializes
one copy — the clustering itself does not.

    PYTHONPATH=src python examples/multiparam_sweep.py
"""

import numpy as np

from repro.cluster import (
    ClusterConfig,
    GeneratorSource,
    avg_f1,
    canonical_labels,
    cluster,
    modularity,
)
from repro.graph.generators import sbm_segments
from repro.graph.stream import edge_list_bytes


def main():
    n, k, avg_degree = 8000, 400, 12
    m = int(n * avg_degree / 2)
    segment, truth = sbm_segments(n, k, p_intra=0.75, seed=1)
    source = GeneratorSource(segment, m, segment_edges=1 << 13)
    v_maxes = (8, 16, 32, 64, 128, 256, 512, 1024)

    res = cluster(source, ClusterConfig(
        n=n, backend="multiparam", v_maxes=v_maxes,
        criterion="density", batch_edges=1 << 13,
    ))
    print(f"streamed sweep: {m} edges, A={len(v_maxes)}; peak edge buffer "
          f"{res.info['peak_buffer_bytes']/1e3:.0f} kB vs "
          f"{edge_list_bytes(m, 4)/1e3:.0f} kB edge list; sweep state "
          f"{(2*len(v_maxes)+1)*n*4/1e3:.0f} kB")

    edges = source.materialize()  # evaluation only: Q/F1 need the graph
    print(f"{'v_max':>6s} {'entropy':>8s} {'density':>8s} "
          f"{'Q':>7s} {'F1':>7s}   (Q/F1 need the graph; selector does not)")
    sweep_labels = res.info["sweep_labels"]
    for a, row in enumerate(res.info["rows"]):
        c = canonical_labels(np.asarray(sweep_labels[a]))
        mark = " <= selected" if a == res.info["best_index"] else ""
        print(f"{row['v_max']:6d} {row['entropy']:8.3f} {row['density']:8.3f} "
              f"{modularity(edges, c):7.3f} {avg_f1(c, truth):7.3f}{mark}")


if __name__ == "__main__":
    main()
