"""Paper §2.5: one pass over the stream, many v_max values, edge-free
selection — then compare the selector's pick to the hindsight-best.

    PYTHONPATH=src python examples/multiparam_sweep.py
"""

import numpy as np

from repro.cluster import ClusterConfig, avg_f1, canonical_labels, cluster, modularity
from repro.graph.generators import sbm_stream


def main():
    n = 8000
    edges, truth = sbm_stream(n, 400, avg_degree=12, p_intra=0.75, seed=1)
    res = cluster(edges, ClusterConfig(
        n=n, backend="multiparam",
        v_maxes=(8, 16, 32, 64, 128, 256, 512, 1024),
        criterion="density",
    ))

    print(f"{'v_max':>6s} {'entropy':>8s} {'density':>8s} "
          f"{'Q':>7s} {'F1':>7s}   (Q/F1 need the graph; selector does not)")
    sweep_labels = res.info["sweep_labels"]
    for a, row in enumerate(res.info["rows"]):
        c = canonical_labels(np.asarray(sweep_labels[a]))
        mark = " <= selected" if a == res.info["best_index"] else ""
        print(f"{row['v_max']:6d} {row['entropy']:8.3f} {row['density']:8.3f} "
              f"{modularity(edges, c):7.3f} {avg_f1(c, truth):7.3f}{mark}")


if __name__ == "__main__":
    main()
