"""Paper §2.5: one pass over the stream, many v_max values, edge-free
selection — then compare the selector's pick to the hindsight-best.

    PYTHONPATH=src python examples/multiparam_sweep.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core.metrics import avg_f1, modularity
from repro.core.multiparam import cluster_stream_multiparam, select_result
from repro.core.streaming import canonical_labels
from repro.graph.generators import sbm_stream


def main():
    n = 8000
    edges, truth = sbm_stream(n, 400, avg_degree=12, p_intra=0.75, seed=1)
    v_maxes = jnp.asarray([8, 16, 32, 64, 128, 256, 512, 1024])
    sweep = cluster_stream_multiparam(jnp.asarray(edges), v_maxes, n)

    print(f"{'v_max':>6s} {'entropy':>8s} {'density':>8s} "
          f"{'Q':>7s} {'F1':>7s}   (Q/F1 need the graph; selector does not)")
    sel = select_result(sweep, criterion="density")
    for a, row in enumerate(sel["rows"]):
        c = canonical_labels(np.asarray(sweep.c[a]))
        mark = " <= selected" if a == sel["best_index"] else ""
        print(f"{row['v_max']:6d} {row['entropy']:8.3f} {row['density']:8.3f} "
              f"{modularity(edges, c):7.3f} {avg_f1(c, truth):7.3f}{mark}")


if __name__ == "__main__":
    main()
