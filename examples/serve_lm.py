"""Batched serving example: prefill a batch of prompts, decode greedily with
the KV cache, report throughput.

    PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch.serve import main as serve_main


def main():
    serve_main([
        "--arch", "qwen1.5-0.5b", "--smoke",
        "--batch", "8", "--prompt-len", "64", "--gen", "32",
    ])


if __name__ == "__main__":
    main()
