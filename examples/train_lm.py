"""End-to-end training driver: a ~60M-param gemma3-style model for a few
hundred steps on the synthetic pipeline, with checkpoint + resume.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.gemma3_1b import FULL
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import make_pipeline
from repro.dist.fault_tolerance import HeartbeatMonitor
from repro.optim.adamw import AdamW
from repro.optim.schedule import cosine_schedule
from repro.train.train_step import init_train_state, make_train_step

# ~60M params: gemma3 family scaled down (same 5:1 local:global pattern).
CFG = FULL.replace(
    n_layers=12, d_model=512, n_heads=8, n_kv_heads=2, head_dim=64,
    d_ff=1536, vocab_size=32768, window=128,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    print(f"model: {CFG.name}-mini "
          f"({sum(x.size for x in jax.tree.leaves(jax.eval_shape(lambda: __import__('repro.models.transformer', fromlist=['init_params']).init_params(jax.random.PRNGKey(0), CFG))))/1e6:.0f}M params)")
    opt = AdamW(m_dtype="bfloat16")  # quantised-state option exercised
    lr_fn = cosine_schedule(3e-3, warmup_steps=20, total_steps=args.steps)
    step = jax.jit(
        make_train_step(CFG, opt, lr_fn, ce_chunk=args.seq),
        donate_argnums=0,
    )
    state = init_train_state(jax.random.PRNGKey(0), CFG, opt)
    pipe = make_pipeline(CFG, args.batch, args.seq)
    ckpt = CheckpointManager(tempfile.mkdtemp(), keep=2)
    mon = HeartbeatMonitor()

    for i in range(args.steps):
        mon.step_start()
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        state, m = step(state, batch)
        mon.step_end(i)
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):7.4f}  "
                  f"lr {float(m['lr']):.2e}  {mon.median:.2f}s/step")
        if (i + 1) % 100 == 0:
            ckpt.save(i + 1, {"state": state, "data": pipe.state_dict()})
            print(f"  checkpointed step {i+1}")


if __name__ == "__main__":
    main()
