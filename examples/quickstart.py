"""Quickstart: the unified ``repro.cluster`` API (canonical snippet, DESIGN.md §6).

One config-driven call — ``cluster(edges, ClusterConfig(...))`` — reaches
every backend; ``StreamClusterer`` ingests the same stream incrementally.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.cluster import (
    ClusterConfig,
    StreamClusterer,
    avg_f1,
    cluster,
    modularity,
)
from repro.graph.generators import sbm_stream


def main():
    # A planted-community graph, streamed in random edge order (paper §2.1).
    n, k = 5000, 250
    edges, truth = sbm_stream(n, k, avg_degree=14, p_intra=0.8, seed=0)
    print(f"graph: {n} nodes, {len(edges)} streamed edges, {k} communities")

    # 1. Paper-faithful sequential Algorithm 1 (numpy loop).
    seq = cluster(edges, ClusterConfig(n=n, v_max=64, backend="dense"))
    print(f"[sequential  ] Q={modularity(edges, seq.labels):.3f} "
          f"F1={avg_f1(seq.labels, truth):.3f} {seq.community_stats}")

    # 2. TPU-adapted chunked tier (jit; quality parity measured in tests).
    chk = cluster(edges, ClusterConfig(n=n, v_max=64, backend="chunked",
                                       chunk=2048))
    print(f"[chunked     ] Q={modularity(edges, chk.labels):.3f} "
          f"F1={avg_f1(chk.labels, truth):.3f}")

    # 3. One-pass multi-v_max sweep + edge-free selection (paper §2.5).
    sweep = cluster(edges, ClusterConfig(
        n=n, backend="multiparam", v_maxes=(16, 32, 64, 128, 256, 512)))
    print(f"[sweep pick  ] v_max={sweep.info['best_v_max']} "
          f"Q={modularity(edges, sweep.labels):.3f} "
          f"F1={avg_f1(sweep.labels, truth):.3f}")
    for row in sweep.info["rows"]:
        print(f"    v_max={row['v_max']:4d} entropy={row['entropy']:.2f} "
              f"density={row['density']:.3f}")

    # 4. Incremental ingestion: edges arrive in batches; identical labels to
    #    the one-shot call for the sequential backends.
    sc = StreamClusterer(ClusterConfig(n=n, v_max=64, backend="scan"))
    for batch in np.array_split(edges, 10):
        sc.partial_fit(batch)
    inc = sc.finalize()
    ref = cluster(edges, ClusterConfig(n=n, v_max=64, backend="scan"))
    print(f"[partial_fit ] 10 batches, {sc.edges_seen} edges, "
          f"identical to one-shot: {np.array_equal(inc.labels, ref.labels)}")


if __name__ == "__main__":
    main()
