"""Quickstart: stream a graph through the paper's clustering algorithm.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core.chunked import cluster_stream_chunked
from repro.core.metrics import avg_f1, community_stats, modularity, nmi
from repro.core.multiparam import cluster_stream_multiparam, select_result
from repro.core.streaming import canonical_labels, cluster_stream_dense
from repro.graph.generators import sbm_stream


def main():
    # A planted-community graph, streamed in random edge order (paper §2.1).
    n, k = 5000, 250
    edges, truth = sbm_stream(n, k, avg_degree=14, p_intra=0.8, seed=0)
    print(f"graph: {n} nodes, {len(edges)} streamed edges, {k} communities")

    # 1. Paper-faithful sequential Algorithm 1 (numpy oracle).
    c_seq, d, v = cluster_stream_dense(edges, v_max=64, n=n)
    print(f"[sequential  ] Q={modularity(edges, c_seq):.3f} "
          f"F1={avg_f1(canonical_labels(c_seq), truth):.3f} "
          f"{community_stats(c_seq)}")

    # 2. TPU-adapted chunked tier (jit; quality parity measured in tests).
    c_chk, _, _ = cluster_stream_chunked(jnp.asarray(edges), 64, n, chunk=2048)
    c_chk = np.asarray(c_chk)
    print(f"[chunked     ] Q={modularity(edges, c_chk):.3f} "
          f"F1={avg_f1(canonical_labels(c_chk), truth):.3f}")

    # 3. One-pass multi-v_max sweep + edge-free selection (paper §2.5).
    sweep = cluster_stream_multiparam(
        jnp.asarray(edges), jnp.asarray([16, 32, 64, 128, 256, 512]), n
    )
    sel = select_result(sweep, criterion="density")
    c_best = sel["labels"]
    print(f"[sweep pick  ] v_max={sel['best_v_max']} "
          f"Q={modularity(edges, c_best):.3f} "
          f"F1={avg_f1(canonical_labels(c_best), truth):.3f}")
    for row in sel["rows"]:
        print(f"    v_max={row['v_max']:4d} entropy={row['entropy']:.2f} "
              f"density={row['density']:.3f}")


if __name__ == "__main__":
    main()
