"""Quickstart: the unified ``repro.cluster`` API (canonical snippet, DESIGN.md §6).

One config-driven call — ``cluster(edges, ClusterConfig(...))`` — reaches
every backend; ``StreamClusterer`` ingests the same stream incrementally;
``edges`` can just as well be a file path or ``EdgeSource`` that never
materializes (DESIGN.md §"Ingestion").

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import signal
import tempfile

import numpy as np

from repro.cluster import (
    ClusterConfig,
    CodecFileSource,
    DeltaVarintCodec,
    EdgeListFileSource,
    StreamClusterer,
    avg_f1,
    cluster,
    modularity,
)
from repro.graph.generators import sbm_stream
from repro.graph.stream import edge_list_bytes, state_bytes


def main():
    # A planted-community graph, streamed in random edge order (paper §2.1).
    n, k = 5000, 250
    edges, truth = sbm_stream(n, k, avg_degree=14, p_intra=0.8, seed=0)
    print(f"graph: {n} nodes, {len(edges)} streamed edges, {k} communities")

    # 1. Paper-faithful sequential Algorithm 1 (numpy loop).
    seq = cluster(edges, ClusterConfig(n=n, v_max=64, backend="dense"))
    print(f"[sequential  ] Q={modularity(edges, seq.labels):.3f} "
          f"F1={avg_f1(seq.labels, truth):.3f} {seq.community_stats}")

    # 2. TPU-adapted chunked tier (jit; quality parity measured in tests).
    chk = cluster(edges, ClusterConfig(n=n, v_max=64, backend="chunked",
                                       chunk=2048))
    print(f"[chunked     ] Q={modularity(edges, chk.labels):.3f} "
          f"F1={avg_f1(chk.labels, truth):.3f}")

    # 3. One-pass multi-v_max sweep + edge-free selection (paper §2.5).
    sweep = cluster(edges, ClusterConfig(
        n=n, backend="multiparam", v_maxes=(16, 32, 64, 128, 256, 512)))
    print(f"[sweep pick  ] v_max={sweep.info['best_v_max']} "
          f"Q={modularity(edges, sweep.labels):.3f} "
          f"F1={avg_f1(sweep.labels, truth):.3f}")
    for row in sweep.info["rows"]:
        print(f"    v_max={row['v_max']:4d} entropy={row['entropy']:.2f} "
              f"density={row['density']:.3f}")

    # 4. Multi-stage refinement (DESIGN.md §11): the same one-pass sweep,
    #    plus a contracted-supergraph refinement at finalize — the sketch is
    #    accumulated during the stream (no second edge pass), "+replay"
    #    re-plays the buffered window through the refined labels.
    ref_ = cluster(edges, ClusterConfig(
        n=n, backend="multiparam", v_maxes=(16, 32, 64, 128, 256, 512),
        refine="labelprop+replay"))
    print(f"[sweep+refine] Q={modularity(edges, ref_.labels):.3f} "
          f"F1={avg_f1(ref_.labels, truth):.3f} "
          f"(sketch peak {ref_.info['refine_sketch_peak_bytes']/1e6:.1f} MB, "
          f"dropped weight {ref_.info['refine_dropped_weight']}, "
          f"replayed {ref_.info['refine_replay_rows']} edges)")

    # 5. Incremental ingestion: edges arrive in batches; identical labels to
    #    the one-shot call for the sequential backends.
    sc = StreamClusterer(ClusterConfig(n=n, v_max=64, backend="scan"))
    for batch in np.array_split(edges, 10):
        sc.partial_fit(batch)
    inc = sc.finalize()
    ref = cluster(edges, ClusterConfig(n=n, v_max=64, backend="scan"))
    print(f"[partial_fit ] 10 batches, {sc.edges_seen} edges, "
          f"identical to one-shot: {np.array_equal(inc.labels, ref.labels)}")

    # 6. Out-of-core ingestion: the same stream from a SNAP-style text file,
    #    parsed in constant memory through the BatchPipeline — the edge list
    #    never materializes.  The paper's memory claim, measured: resident
    #    edges are O(batch_edges) while state is exactly 3n ints.
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "graph.txt")
        with open(path, "w") as f:
            f.write("# i j, one edge per line (SNAP format)\n")
            for i, j in edges:
                f.write(f"{i}\t{j}\n")
        # parse blocks sized to the ingest batch keep total residency tight
        # (the reported peak counts parse blocks AND pipeline batches)
        ooc = cluster(EdgeListFileSource(path, block_lines=4096),
                      ClusterConfig(n=n, v_max=64, backend="scan",
                                    batch_edges=4096))
        print(f"[out-of-core ] file-streamed, identical to in-memory: "
              f"{np.array_equal(ooc.labels, ref.labels)}")
        print(f"    peak edge buffer = "
              f"{ooc.info['peak_buffer_bytes']/1e3:.0f} kB "
              f"(edge list would be {edge_list_bytes(len(edges), 4)/1e3:.0f} kB)"
              f" | state 3n ints = {state_bytes(n)/1e3:.0f} kB")

        # suspend mid-file, resume in a fresh "session", finish the stream
        sc = StreamClusterer(ClusterConfig(n=n, v_max=64, backend="scan",
                                           batch_edges=8192))
        sc.fit(path, max_batches=2)
        ckpt = os.path.join(d, "ckpt")
        sc.save(ckpt)
        sc2 = StreamClusterer.restore(ckpt)
        sc2.fit(path)  # continues at the recorded mid-file offset
        print(f"[resume      ] suspended at row {sc.stream_offset}, resumed "
              f"to {sc2.stream_offset}; identical to one-shot: "
              f"{np.array_equal(sc2.finalize().labels, ref.labels)}")

        # 7. Device-resident compressed ingest (DESIGN.md §14): stage DVE3
        #    payload bytes + a descriptor table instead of decoded edges and
        #    let the device decode them — ``device_decode=True`` (requires
        #    ``megabatch_k``; ``chunked``/``pallas`` backends).  Labels are
        #    bit-identical to host decode either way; blocks that compress
        #    better as varint are host-decoded transparently and counted
        #    (on a graph this tiny that is most of them — the ≥3x host-cost
        #    win on fixed-block streams is measured in benchmarks/smoke.py).
        cpath = os.path.join(d, "graph.dvc3")
        sorted_edges = edges[np.argsort(edges[:, 0], kind="stable")]
        CodecFileSource.write(cpath, sorted_edges.astype(np.int32),
                              DeltaVarintCodec(version=3))
        base = ClusterConfig(n=n, v_max=64, backend="chunked",
                             batch_edges=4096, chunk=4096, megabatch_k=4)
        host = StreamClusterer(base).fit(CodecFileSource(cpath)).finalize()
        dev_ = StreamClusterer(base.replace(device_decode=True)).fit(
            CodecFileSource(cpath)).finalize()
        print(f"[device ingst] decoded on device: "
              f"{dev_.info['device_decoded_megabatches']} megabatches, "
              f"fallback rate "
              f"{dev_.info['device_fallback_segment_rate']:.2f}; identical "
              f"to host decode: {np.array_equal(dev_.labels, host.labels)}")

        # 8. Fault tolerance (DESIGN.md §15): autosave every N rows and a
        #    preemption mid-stream — fit drains the in-flight batch, saves
        #    at the exact batch-boundary cursor, and a fresh process
        #    resumes to labels bit-identical to an uninterrupted run.  A
        #    hard kill (SIGKILL/OOM) skips the drain but resumes the same
        #    way from the newest autosave generation.
        from repro.dist.fault_tolerance import PreemptionHandler

        adir = os.path.join(d, "autosave")
        pre = PreemptionHandler()
        pre.install()
        sc = StreamClusterer(ClusterConfig(
            n=n, v_max=64, backend="scan", batch_edges=8192,
            autosave_every=16384, autosave_dir=adir, retries=3))
        os.kill(os.getpid(), signal.SIGTERM)  # lands at a batch boundary
        sc.fit(path, preemption=pre)
        pre.uninstall()
        sc3 = StreamClusterer.restore(adir)
        sc3.fit(path)  # fresh session finishes the stream
        fin = sc3.finalize()
        print(f"[fault-toler ] preempted at row {sc.stream_offset} "
              f"({sc.autosaves} autosave), resumed to "
              f"{sc3.stream_offset}; identical to one-shot: "
              f"{np.array_equal(fin.labels, ref.labels)}")


if __name__ == "__main__":
    main()
