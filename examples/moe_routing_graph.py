"""Paper-technique ↔ LM-runtime touch-point (DESIGN.md §9): stream the
token–expert co-routing graph of a MoE forward pass through the clusterer to
surface expert-affinity communities — an analysis tool for router health.

Edges: for every token, each pair of its top-k experts is one edge in a
stream over expert ids.  Dense expert communities = experts that co-fire;
a router collapse shows up as one giant community.  The stream arrives
batch-by-batch through ``StreamClusterer.partial_fit`` — exactly how a
router monitor would consume routing decisions during serving.

    PYTHONPATH=src python examples/moe_routing_graph.py
"""

import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster import ClusterConfig, StreamClusterer
from repro.configs.registry import get_smoke_config
from repro.models.transformer import init_params


def main():
    cfg = get_smoke_config("phi3.5-moe-42b-a6.6b").replace(
        n_experts=16, top_k=2, d_expert=32
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 128), 0,
                                cfg.vocab_size)

    # Recover routing decisions from the first MoE block's router.
    from repro.models.layers import rms_norm
    x = params["embed"][tokens]
    block = jax.tree.map(lambda a: a[0], params["cycles"][0])
    h = rms_norm(x, block["ln2"]).reshape(-1, cfg.d_model)
    logits = jnp.einsum("td,de->te", h.astype(jnp.float32), block["router"])
    _, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.top_k)
    idx = np.asarray(idx)

    edges = np.array(
        [pair for row in idx for pair in itertools.combinations(sorted(row), 2)
         if pair[0] != pair[1]],
        dtype=np.int32,
    )
    rng = np.random.default_rng(0)
    rng.shuffle(edges, axis=0)
    print(f"co-routing stream: {len(edges)} edges over {cfg.n_experts} experts")

    # Incremental ingestion, one partial_fit per "serving step".
    sc = StreamClusterer(ClusterConfig(
        n=cfg.n_experts, v_max=max(len(edges) // 4, 1), backend="dense"))
    for batch in np.array_split(edges, 8):
        sc.partial_fit(batch)
    res = sc.finalize()
    print("expert -> community:", dict(enumerate(res.labels.tolist())))
    print("stats:", res.community_stats)


if __name__ == "__main__":
    main()
