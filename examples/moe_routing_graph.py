"""Paper-technique ↔ LM-runtime touch-point (DESIGN.md §9 → §10): stream the
token–expert co-routing graph of a MoE forward pass through the clusterer to
surface expert-affinity communities — an analysis tool for router health.

Edges: for every token, each pair of its top-k experts is one edge in a
stream over expert ids.  Dense expert communities = experts that co-fire;
a router collapse shows up as one giant community.  The stream reaches the
clusterer through a ``GeneratorSource``-style adapter: routing decisions are
turned into edge segments *lazily, per serving step* — the monitor drains
one source batch per step instead of materializing per-step edge arrays, so
its residency is O(step) edges and ``3 n_experts`` ints of state no matter
how long the serving run is.

    PYTHONPATH=src python examples/moe_routing_graph.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster import (
    BatchPipeline,
    ClusterConfig,
    GeneratorSource,
    StreamClusterer,
)
from repro.configs.registry import get_smoke_config
from repro.models.transformer import init_params


def routing_edge_source(idx: np.ndarray, tokens_per_step: int) -> GeneratorSource:
    """Adapt top-2 routing decisions to an :class:`EdgeSource`.

    ``idx``: (T, 2) expert ids per token, in serving order.  Row ``t`` of the
    stream is token ``t``'s co-routing pair — computed on demand from the
    routing decisions (deterministic per absolute offset, so the monitor can
    suspend/resume mid-serving like any other source), never stored as a
    materialized edge array.  ``tokens_per_step`` sets the segment size: one
    segment = one serving step's worth of decisions.
    """
    if idx.shape[1] != 2:
        raise ValueError(f"expected top-2 routing, got top-{idx.shape[1]}")

    def segment(start: int, length: int) -> np.ndarray:
        return np.sort(idx[start : start + length], axis=1).astype(np.int32)

    return GeneratorSource(segment, len(idx), segment_edges=tokens_per_step)


def main():
    cfg = get_smoke_config("phi3.5-moe-42b-a6.6b").replace(
        n_experts=16, top_k=2, d_expert=32
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 128), 0,
                                cfg.vocab_size)

    # Recover routing decisions from the first MoE block's router.
    from repro.models.layers import rms_norm
    x = params["embed"][tokens]
    block = jax.tree.map(lambda a: a[0], params["cycles"][0])
    h = rms_norm(x, block["ln2"]).reshape(-1, cfg.d_model)
    logits = jnp.einsum("td,de->te", h.astype(jnp.float32), block["router"])
    _, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.top_k)
    idx = np.asarray(idx)

    # One source, drained one batch per "serving step" — the router monitor
    # consumes decisions as they arrive, in serving order.
    tokens_per_step = 64
    source = routing_edge_source(idx, tokens_per_step)
    print(f"co-routing stream: {source.n_edges} edges over "
          f"{cfg.n_experts} experts, {tokens_per_step} tokens/step")

    sc = StreamClusterer(ClusterConfig(
        n=cfg.n_experts, v_max=max(source.n_edges // 4, 1), backend="dense"))
    pipe = BatchPipeline(source, tokens_per_step, prefetch=1)
    steps = 0
    for batch in pipe:  # one partial_fit per serving step, one pipeline
        sc.partial_fit(batch.edges, raw_rows=batch.n_rows)
        steps += 1
    res = sc.finalize()
    print(f"drained {steps} serving steps; peak edge buffer "
          f"{pipe.peak_buffer_bytes} B (per-step, not per-run)")
    print("expert -> community:", dict(enumerate(res.labels.tolist())))
    print("stats:", res.community_stats)


if __name__ == "__main__":
    main()
